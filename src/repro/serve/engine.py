"""Slot-based continuous-batching decode engine over a paged KV cache.

The engine owns a fixed-capacity decode batch of ``n_slots`` slots. Every
attention layer reads/writes a preallocated physical block pool through a
per-slot block table (:func:`repro.models.attention.paged_decode_attention`);
recurrent layers (mamba2 / rwkv6 / rwkv channel-mix) keep per-slot state
rows — their state is O(1) per slot, there is nothing to page. The whole
decode step — token sample, cache update, per-slot done flags — is ONE
jitted program with the engine state donated, so steady-state serving is
one dispatch per generated-token wavefront regardless of temperature.

Exactness contract (pinned by ``tests/test_serve.py``): with greedy
decode the engine emits byte-identical tokens to the static
``launch.serve.generate`` path for every request, including requests
admitted mid-stream. This holds because (a) paged attention gathers
blocks in position order, so with natural-layout prefill the assembled
keys equal the dense cache bitwise, and (b) per-row batched compute is
bitwise independent of the other rows in the batch on this backend.

Paging: each admitted slot gets ``blocks_per_slot`` physical blocks from
a free list (shuffled by churn — the block table is real indirection,
not an identity map). One extra scratch block is reserved: released
slots' table rows all point at it, so their continued in-program decode
writes land somewhere harmless and are never read (the ``p <= pos``
visibility mask only exposes positions the owner actually wrote).

Right-padded bucketed prefill is exact for attention layers (pad-position
cache garbage is masked until decode overwrites it) but NOT for
recurrent state, which consumes pad tokens. The engine therefore pads
prompts up to power-of-two buckets only for pure-attention archs and
requires exact-length prefill groups otherwise (``pad_ok``).

Checkpoint hot-swap: :meth:`SlotEngine.swap_params` installs new params
via a param-donating jitted copy (same shapes -> no recompile, no second
resident copy). In-flight slots keep their KV built under the old
params; only tokens sampled after the swap boundary change.
"""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.requests import Request


def _pow2_ceil(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def model_pads_ok(model) -> bool:
    """True when every layer is pure attention (no recurrent mixer, no
    rwkv channel-mix ffn) — the archs for which right-padded bucketed
    prefill is exact."""
    return all(ls.mixer in ("attn", "shared_attn") and ls.ffn != "rwkv_cm"
               for seg in model.cfg.segments for ls in seg.pattern)


class SlotEngine:
    """Continuous-batching decode engine. See module docstring.

    Parameters: ``n_slots`` decode batch capacity; ``max_len`` the cache
    span every slot must cover (prompt + generation); ``block_size``
    physical KV block length (default: one block spans ``max_len``, the
    dense-identical configuration); ``eos`` optional early-stop token;
    ``temperature``/``seed`` sampling controls baked into the step
    program; ``prefill_batch`` caps prefill rows per admission group
    (groups pad to the next power of two of their size, so recompiles
    are bounded by buckets x log2(prefill_batch), not group sizes).
    """

    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 block_size: int = 0, eos: int | None = None,
                 temperature: float = 0.0, seed: int = 0,
                 prefill_batch: int = 0):
        if model.cfg.prefix_len:
            raise ValueError("SlotEngine serves token-only archs "
                             f"(prefix_len={model.cfg.prefix_len})")
        self.model = model
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.block_size = int(block_size) or self.max_len
        self.blocks_per_slot = -(-self.max_len // self.block_size)
        self.eos = eos
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.prefill_batch = int(prefill_batch) or self.n_slots
        self.pad_ok = model_pads_ok(model)

        n_pool = self.n_slots * self.blocks_per_slot
        self.scratch_block = n_pool  # last pool index, never allocated
        self._free_blocks = list(range(n_pool))
        self._free_slots = list(range(self.n_slots))
        self._table_np = np.full((self.n_slots, self.blocks_per_slot),
                                 self.scratch_block, np.int32)
        self._table = jnp.asarray(self._table_np)
        self._slot_req: dict[int, Request] = {}
        self._active_np = np.zeros(self.n_slots, bool)

        self._params = params
        self._state = {
            "caches": model.init_paged_cache(self.n_slots, n_pool + 1,
                                             self.block_size),
            "logits": jnp.zeros((self.n_slots, model.cfg.vocab),
                                jnp.float32),
            "pos": jnp.zeros(self.n_slots, jnp.int32),
            "gen": jnp.zeros(self.n_slots, jnp.int32),
            "max_gen": jnp.ones(self.n_slots, jnp.int32),
            "active": jnp.zeros(self.n_slots, bool),
            "rid": jnp.zeros(self.n_slots, jnp.int32),
        }

        self._step_c = jax.jit(self._step_fn, donate_argnums=(1,))
        self._prefill_c = jax.jit(model.prefill_at)
        self._insert_c = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._swap_c = jax.jit(
            lambda old, new: jax.tree.map(jnp.copy, new),
            donate_argnums=(0,))

        self.compile_s = 0.0
        self.steps = 0
        self.tokens_out = 0
        self.swaps = 0
        self._occupancy_sum = 0

    # ---------------------------------------------------------------- jit
    def _step_fn(self, params, state, table):
        """ONE decode wavefront: sample every slot's next token from its
        held logits, run the paged decode step, update gen counts and
        done flags. Inactive slots sample token 0 and write to scratch."""
        logits, active = state["logits"], state["active"]
        if self.temperature > 0:
            base = jax.random.PRNGKey(self.seed)
            keys = jax.vmap(lambda r, g: jax.random.fold_in(
                jax.random.fold_in(base, r), g))(state["rid"], state["gen"])
            tok = jax.vmap(lambda k, l: jax.random.categorical(
                k, l / self.temperature))(keys, logits)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = jnp.where(active, tok.astype(jnp.int32), 0)
        new_logits, caches = self.model.decode_step(
            params, state["caches"], tok, state["pos"], table)
        gen = state["gen"] + active.astype(jnp.int32)
        hit = gen >= state["max_gen"]
        if self.eos is not None:
            hit |= tok == jnp.int32(self.eos)
        done = active & hit
        new_state = {
            "caches": caches,
            "logits": new_logits,
            "pos": state["pos"] + 1,
            "gen": gen,
            "max_gen": state["max_gen"],
            "active": active & ~done,
            "rid": state["rid"],
        }
        return new_state, tok, done

    def _insert_fn(self, state, pre, logits, table_rows, slots, next_pos,
                   max_gen, rid, active):
        """Scatter one prefill batch into the engine state. Padded
        duplicate rows carry identical values, so repeated-index scatters
        commute (deterministic)."""
        return {
            "caches": self.model.insert_prefill(state["caches"], pre,
                                                table_rows, slots),
            "logits": state["logits"].at[slots].set(
                logits.astype(state["logits"].dtype)),
            "pos": state["pos"].at[slots].set(next_pos),
            "gen": state["gen"].at[slots].set(0),
            "max_gen": state["max_gen"].at[slots].set(max_gen),
            "active": state["active"].at[slots].set(active),
            "rid": state["rid"].at[slots].set(rid),
        }

    # --------------------------------------------------------------- host
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return int(self._active_np.sum())

    def bucket_len(self, n: int) -> int:
        """Prefill bucket for an n-token prompt: next power of two for
        pad-safe archs, the exact length otherwise."""
        return min(_pow2_ceil(n), self.max_len) if self.pad_ok else n

    def admit(self, reqs: list[Request]) -> None:
        """Admit one prefill group. All requests must share a bucket
        (scheduler's job); the group is padded to a power-of-two row
        count by repeating row 0, bounding compiles per bucket."""
        if not reqs:
            return
        if len(reqs) > self.free_slots:
            raise ValueError(f"admitting {len(reqs)} requests with only "
                             f"{self.free_slots} free slots")
        if len(reqs) > self.prefill_batch:
            raise ValueError(f"group of {len(reqs)} exceeds prefill_batch="
                             f"{self.prefill_batch}")
        buckets = {self.bucket_len(r.prompt_len) for r in reqs}
        if len(buckets) != 1:
            raise ValueError(f"mixed prefill buckets in one group: "
                             f"{sorted(buckets)}")
        bucket = buckets.pop()
        for r in reqs:
            if r.prompt_len + r.max_gen > self.max_len:
                raise ValueError(
                    f"request {r.rid}: {r.prompt_len}+{r.max_gen} tokens "
                    f"exceed max_len={self.max_len}")

        # pad rows to the next power of two of the group size (not the
        # full prefill batch): single-slot joins at saturation pay a
        # 1-row prefill, and compiles stay bounded by
        # |buckets| x log2(prefill_batch) programs (all warmed)
        n, p = len(reqs), min(self.prefill_batch, _pow2_ceil(len(reqs)))
        toks = np.zeros((p, bucket), np.int32)
        lengths = np.empty(p, np.int32)
        slots = np.empty(p, np.int32)
        rows = np.empty((p, self.blocks_per_slot), np.int32)
        next_pos = np.empty(p, np.int32)
        max_gen = np.empty(p, np.int32)
        rid = np.empty(p, np.int32)
        for i, r in enumerate(reqs):
            s = self._free_slots.pop()
            blocks = [self._free_blocks.pop()
                      for _ in range(self.blocks_per_slot)]
            self._table_np[s] = blocks
            toks[i, :r.prompt_len] = r.tokens
            lengths[i] = r.prompt_len
            slots[i] = s
            rows[i] = blocks
            next_pos[i] = r.prompt_len
            max_gen[i] = r.max_gen
            rid[i] = r.rid
            self._slot_req[s] = r
            self._active_np[s] = True
        for i in range(n, p):  # duplicate row 0: identical-value scatters
            toks[i], lengths[i], slots[i] = toks[0], lengths[0], slots[0]
            rows[i], next_pos[i] = rows[0], next_pos[0]
            max_gen[i], rid[i] = max_gen[0], rid[0]

        logits, pre, pos = self._prefill_c(
            self._params, jnp.asarray(toks), jnp.asarray(lengths))
        self._table = jnp.asarray(self._table_np)
        self._state = self._insert_c(
            self._state, pre, logits, jnp.asarray(rows), jnp.asarray(slots),
            jnp.asarray(next_pos), jnp.asarray(max_gen), jnp.asarray(rid),
            jnp.ones(p, bool))

    def step(self):
        """One decode wavefront. Appends each live slot's sampled token to
        its request's ``out`` and returns ``(emitted, finished)``: the
        requests that received a token this step, and the subset whose
        slot was recycled (EOS or generation budget hit)."""
        live = np.nonzero(self._active_np)[0]
        self._state, tok, done = self._step_c(self._params, self._state,
                                              self._table)
        tok = np.asarray(tok)
        done = np.asarray(done)
        emitted = []
        for s in live:
            r = self._slot_req[int(s)]
            r.out.append(int(tok[s]))
            emitted.append(r)
        finished = [self._release(int(s)) for s in np.nonzero(done)[0]]
        if finished:
            self._table = jnp.asarray(self._table_np)
        self.steps += 1
        self._occupancy_sum += len(emitted)
        self.tokens_out += len(emitted)
        return emitted, finished

    def _release(self, s: int) -> Request:
        self._free_blocks.extend(int(b) for b in self._table_np[s])
        self._table_np[s] = self.scratch_block
        self._active_np[s] = False
        self._free_slots.append(s)
        return self._slot_req.pop(s)

    def swap_params(self, new_params) -> None:
        """Install a new checkpoint without dropping in-flight slots: a
        param-donating jitted copy (same shapes -> no recompile, the old
        buffers are freed as the copy lands). Tokens sampled after this
        call use the new params; each slot's existing KV was built under
        the old ones — the standard continuous-serving boundary."""
        old_td = jax.tree.structure(self._params)
        new_td = jax.tree.structure(new_params)
        if old_td != new_td:
            raise ValueError("hot-swap params tree mismatch: "
                             f"{old_td} != {new_td}")
        self._params = self._swap_c(self._params, new_params)
        self.swaps += 1

    def warmup(self, buckets=()) -> float:
        """Compile the step and the prefill/insert path for each bucket
        before serving, so steady-state numbers exclude compile time.
        Runs against the live state: all slots are inactive and every
        table row points at the scratch block, so the warm-up writes are
        invisible (active=False inserts never activate a slot)."""
        t0 = time.perf_counter()
        self._state, tok, _ = self._step_c(self._params, self._state,
                                           self._table)
        jax.block_until_ready(tok)
        row_counts = []
        p = 1
        while p < self.prefill_batch:
            row_counts.append(p)
            p *= 2
        row_counts.append(self.prefill_batch)
        for bucket in sorted({self.bucket_len(b) for b in buckets}):
            for p in row_counts:
                toks = jnp.zeros((p, bucket), jnp.int32)
                lengths = jnp.ones(p, jnp.int32)
                logits, pre, _ = self._prefill_c(self._params, toks, lengths)
                rows = jnp.full((p, self.blocks_per_slot),
                                self.scratch_block, jnp.int32)
                zeros = jnp.zeros(p, jnp.int32)
                self._state = self._insert_c(
                    self._state, pre, logits, rows, zeros, zeros,
                    jnp.ones(p, jnp.int32), zeros, jnp.zeros(p, bool))
        jax.block_until_ready(self._state["logits"])
        self.compile_s = time.perf_counter() - t0
        return self.compile_s

    def stats(self) -> dict:
        return {
            "steps": self.steps,
            "tokens_out": self.tokens_out,
            "occupancy_mean": round(self._occupancy_sum / self.steps /
                                    self.n_slots, 3) if self.steps else 0.0,
            "swaps": self.swaps,
            "compile_s": round(self.compile_s, 3),
            "free_slots": self.free_slots,
        }

"""Continuous-batching serving plane: slot engine, paged KV cache
scheduling, and federated checkpoint hot-swap (see ROADMAP "Serving
plane")."""
from repro.serve.engine import SlotEngine, model_pads_ok
from repro.serve.requests import Request, poisson_workload
from repro.serve.scheduler import (
    ServeReport,
    StepClock,
    WallClock,
    serve_continuous,
    serve_static,
)

__all__ = [
    "Request",
    "ServeReport",
    "SlotEngine",
    "StepClock",
    "WallClock",
    "model_pads_ok",
    "poisson_workload",
    "serve_continuous",
    "serve_static",
]

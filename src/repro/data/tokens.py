"""Synthetic federated token pipeline for LM training.

Each client gets its own bigram-ish generative process (a per-client "topic"
mixture over token ranges) so that the federated split is genuinely non-iid —
client gradients disagree, which is what makes the DP-PASGD averaging period
tau matter. Deterministic given (seed, client).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenTaskConfig:
    vocab: int
    seq_len: int
    n_clients: int
    topics_per_client: int = 4
    topic_width: int = 256      # token-range width of one topic
    noniid: float = 0.8         # prob. of drawing from the client's topics
    seed: int = 0


class FederatedTokenStream:
    """sampler(client, tau, rng) -> {"tokens": (tau,B,S), "labels": ...}"""

    def __init__(self, cfg: TokenTaskConfig, batch_size: int,
                 prefix_len: int = 0, d_model: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.prefix_len = prefix_len
        self.d_model = d_model
        root = np.random.default_rng(cfg.seed)
        # one (n_clients, topics) block, not a per-client list: at virtual-
        # population scale (n_clients = M up to 10^6, repro.population) the
        # topic table is the stream's only O(M) state and must stay a few
        # MB of one array rather than a million tiny ones
        self.client_topics = root.integers(
            0, max(1, cfg.vocab - cfg.topic_width),
            size=(cfg.n_clients, cfg.topics_per_client))

    def _sample_tokens(self, client: int, n: int,
                       rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        topics = self.client_topics[client]
        # choose a topic per sequence; walk within the topic band with noise
        t = rng.choice(topics, size=(n, 1))
        in_topic = rng.random((n, cfg.seq_len + 1)) < cfg.noniid
        band = t + rng.integers(0, cfg.topic_width, size=(n, cfg.seq_len + 1))
        uniform = rng.integers(0, cfg.vocab, size=(n, cfg.seq_len + 1))
        toks = np.where(in_topic, band, uniform).astype(np.int32)
        return np.clip(toks, 0, cfg.vocab - 1)

    def sampler(self, client: int, tau: int, rng: np.random.Generator):
        n = tau * self.batch_size
        toks = self._sample_tokens(client, n, rng)
        toks = toks.reshape(tau, self.batch_size, self.cfg.seq_len + 1)
        batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        if self.prefix_len:
            batch["prefix"] = rng.standard_normal(
                (tau, self.batch_size, self.prefix_len, self.d_model)
            ).astype(np.float32) * 0.02
        return batch

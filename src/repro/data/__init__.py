from repro.data.federated import (
    ClientData,
    FederatedData,
    split_by_group,
    split_dirichlet,
    split_iid,
)
from repro.data.synthetic import Dataset, adult_like, vehicle_like

__all__ = [
    "ClientData", "FederatedData", "split_by_group", "split_dirichlet",
    "split_iid", "Dataset", "adult_like", "vehicle_like",
]

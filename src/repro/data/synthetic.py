"""Synthetic surrogates for the paper's datasets (offline data gate).

The real Adult (UCI) and Vehicle (Duarte & Hu) datasets are not available in
this container. We generate statistically matched surrogates:

  - ``adult_like``: 32,561 samples, 14 mixed categorical/numerical attributes
    one-hot encoded (we keep d=104 features, matching a standard Adult
    encoding), binary income label, plus a 16-level ``education`` categorical
    used for the paper's non-iid split. Education level shifts both the
    feature distribution and the label rate, so splitting by education yields
    genuinely non-iid clients (as in Adult-1).
  - ``vehicle_like``: 23 sensors x ~1,899 samples, 100 acoustic/seismic
    features, binary AAV/DW label. Each sensor has its own feature covariance
    rotation + bias (sensor placement), giving the Vehicle-1 non-iid-ness.

Features are normalized to the unit ball (paper §4 assumption).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

ADULT_EDU_LEVELS = [
    "Bachelors", "Some-college", "11th", "HS-grad", "Prof-school",
    "Assoc-acdm", "Assoc-voc", "9th", "7th-8th", "12th", "Masters",
    "1st-4th", "10th", "Doctorate", "5th-6th", "Preschool",
]
# Rough relative frequencies of education levels in Adult (sums to 1).
_EDU_FREQ = np.array([0.165, 0.224, 0.036, 0.322, 0.018, 0.033, 0.042, 0.016,
                      0.020, 0.013, 0.053, 0.005, 0.029, 0.013, 0.010, 0.002])
_EDU_FREQ = _EDU_FREQ / _EDU_FREQ.sum()
# Education strongly predicts income: P(>50k | edu) ranges ~1% .. ~74%.
_EDU_POS_RATE = np.array([0.41, 0.19, 0.05, 0.16, 0.74, 0.25, 0.26, 0.05,
                          0.06, 0.07, 0.56, 0.04, 0.07, 0.73, 0.05, 0.01])


@dataclass
class Dataset:
    x: np.ndarray          # (N, d) float32, rows in unit ball
    y: np.ndarray          # (N,) int32 in {0, 1}
    group: np.ndarray      # (N,) int32 grouping attribute (education / sensor)
    name: str

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def dim(self) -> int:
        return self.x.shape[1]


def _unit_ball(x: np.ndarray) -> np.ndarray:
    """Normalize every row into the unit ball (paper §4)."""
    norms = np.linalg.norm(x, axis=1, keepdims=True)
    return (x / np.maximum(norms, 1.0)).astype(np.float32)


def adult_like(n: int = 32_561, dim: int = 104, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    edu = rng.choice(16, size=n, p=_EDU_FREQ).astype(np.int32)
    # class-conditional, education-conditional Gaussian features
    base_dir = rng.normal(size=(16, dim)) / np.sqrt(dim)       # edu shift
    label_dir = rng.normal(size=dim) / np.sqrt(dim)            # income signal
    y = (rng.random(n) < _EDU_POS_RATE[edu]).astype(np.int32)
    x = rng.normal(scale=0.8, size=(n, dim))
    x += base_dir[edu] * 2.0
    x += np.outer(2.0 * y - 1.0, label_dir) * 0.9
    # sparse one-hot-ish block to mimic categorical encodings
    cat = rng.integers(0, dim // 4, size=n)
    x[np.arange(n), cat] += 1.5
    # ~9% Bayes-irreducible label noise (Adult itself is not separable)
    flip = rng.random(n) < 0.09
    y = np.where(flip, 1 - y, y).astype(np.int32)
    return Dataset(x=_unit_ball(x), y=y, group=edu, name="adult_like")


def vehicle_like(n_sensors: int = 23, per_sensor: int = 1_899, dim: int = 100,
                 seed: int = 1) -> Dataset:
    rng = np.random.default_rng(seed)
    n = n_sensors * per_sensor
    sensor = np.repeat(np.arange(n_sensors, dtype=np.int32), per_sensor)
    y = rng.integers(0, 2, size=n).astype(np.int32)
    label_dir = rng.normal(size=dim) / np.sqrt(dim)
    # per-sensor rotation (placement / terrain) + bias
    x = rng.normal(scale=0.5, size=(n, dim))
    for s in range(n_sensors):
        m = sensor == s
        q, _ = np.linalg.qr(rng.normal(size=(dim, dim)))
        x[m] = x[m] @ (0.7 * np.eye(dim) + 0.3 * q)
        x[m] += rng.normal(scale=0.4, size=dim)
    x += np.outer(2.0 * y - 1.0, label_dir) * 1.1
    flip = rng.random(n) < 0.07
    y = np.where(flip, 1 - y, y).astype(np.int32)
    return Dataset(x=_unit_ball(x), y=y, group=sensor, name="vehicle_like")

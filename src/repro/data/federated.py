"""Federated splits + per-client samplers (paper §8.1 data settings).

Splits:
  - ``split_by_group``  : Adult-1 / Vehicle-1 style non-iid (one attribute
                          value -> one client).
  - ``split_iid``       : Adult-2 / Vehicle-2 style (uniform shuffle, equal
                          client sizes).
  - ``split_dirichlet`` : beyond-paper label-skew control (alpha -> niid-ness).

Each client's data is further divided 80/10/10 train/val/test (paper §8.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.synthetic import Dataset


@dataclass
class ClientData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_val: np.ndarray
    y_val: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_train(self) -> int:
        return self.x_train.shape[0]


@dataclass
class FederatedData:
    clients: list[ClientData]
    name: str = ""

    @property
    def n_clients(self) -> int:
        return len(self.clients)

    def batch_sizes(self, batch_size: int,
                    proportional: bool = False) -> list[int]:
        """Per-step mini-batch size X_m per client.

        Default: uniform ``batch_size`` for every client (sampling is with
        replacement so clients smaller than the batch still work).

        ``proportional=True`` returns the paper's per-client X_m: sizes
        proportional to each client's ``n_train`` with the same *total*
        batch budget (target mean ``batch_size``, floor 1), CAPPED at
        ``batch_size``. The cap is a soundness invariant enforced here,
        not caller etiquette: the engines *sample* a uniform ``batch_size``
        per step (round batches stack to one (C, tau, B, ...) block via
        ``make_sampler(batch_size)``), so an accounted X_m above the
        executed batch would claim a smaller per-step sensitivity (2G/X_m,
        paper §5.2) than the mechanism actually has — a privacy accounting
        hole. Below the executed batch the accounting is merely
        conservative (small clients pay extra noise), which is the safe
        side the cap leaves data-rich clients on.
        """
        if not proportional:
            return [batch_size for _ in self.clients]
        total = sum(c.n_train for c in self.clients)
        budget = batch_size * len(self.clients)
        return [max(1, min(batch_size, round(budget * c.n_train / total)))
                for c in self.clients]

    def make_sampler(self, batch_size: int):
        """sampler(client, tau, rng) -> {'x': (tau,B,d), 'y': (tau,B)}"""
        def sampler(m: int, tau: int, rng: np.random.Generator):
            c = self.clients[m]
            idx = rng.integers(0, c.n_train, size=(tau, batch_size))
            return {"x": c.x_train[idx], "y": c.y_train[idx]}
        return sampler

    def eval_arrays(self, split: str = "test"):
        xs = np.concatenate([getattr(c, f"x_{split}") for c in self.clients])
        ys = np.concatenate([getattr(c, f"y_{split}") for c in self.clients])
        return xs, ys


def _split_client(x: np.ndarray, y: np.ndarray,
                  rng: np.random.Generator) -> ClientData:
    n = x.shape[0]
    perm = rng.permutation(n)
    x, y = x[perm], y[perm]
    n_tr = max(1, int(0.8 * n))
    n_va = max(1, int(0.1 * n))
    return ClientData(
        x_train=x[:n_tr], y_train=y[:n_tr],
        x_val=x[n_tr:n_tr + n_va], y_val=y[n_tr:n_tr + n_va],
        x_test=x[n_tr + n_va:], y_test=y[n_tr + n_va:],
    )


def split_by_group(ds: Dataset, seed: int = 0) -> FederatedData:
    """Non-iid: each distinct ``group`` value becomes one client."""
    rng = np.random.default_rng(seed)
    clients = []
    for g in np.unique(ds.group):
        m = ds.group == g
        clients.append(_split_client(ds.x[m], ds.y[m], rng))
    return FederatedData(clients=clients, name=f"{ds.name}-noniid")


def split_iid(ds: Dataset, n_clients: int, seed: int = 0) -> FederatedData:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(ds.n)
    parts = np.array_split(perm, n_clients)
    clients = [_split_client(ds.x[p], ds.y[p], rng) for p in parts]
    return FederatedData(clients=clients, name=f"{ds.name}-iid")


def split_dirichlet(ds: Dataset, n_clients: int, alpha: float,
                    seed: int = 0) -> FederatedData:
    """Label-skew split: per-class Dirichlet(alpha) allocation over clients."""
    rng = np.random.default_rng(seed)
    idx_by_client: list[list[int]] = [[] for _ in range(n_clients)]
    for cls in np.unique(ds.y):
        idx = np.flatnonzero(ds.y == cls)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for c, part in enumerate(np.split(idx, cuts)):
            idx_by_client[c].extend(part.tolist())
    clients = []
    for c in range(n_clients):
        sel = np.asarray(idx_by_client[c], dtype=int)
        if sel.size < 10:   # guarantee a usable shard
            extra = rng.integers(0, ds.n, size=10)
            sel = np.concatenate([sel, extra])
        clients.append(_split_client(ds.x[sel], ds.y[sel], rng))
    return FederatedData(clients=clients, name=f"{ds.name}-dir{alpha}")

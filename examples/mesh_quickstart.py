"""Mesh quickstart: DP-PASGD on the 2D client x model sharding plane.

The 1D planes (vmap / shard_map) hold one full model replica per client —
fine for the paper's convex models, impossible for the big transformer
configs where ONE replica exceeds a device. ``engine="mesh_2d"`` splits
the device grid into a (client, model) mesh: clients shard over the first
axis exactly like the 1D plane, and every client's params/optimizer state
shard over ``dm`` model shards along the second. This script walks the
whole surface in ~1 minute on CPU:

  1. build the 2D mesh and inspect the logical-axis rules that place each
     weight (``mesh2d_rules``: fsdp/tp/act -> "model", client/batch stay
     unsharded within a shard),
  2. run the same federation on vmap, on the degenerate ``(C, 1)`` mesh
     (bitwise the 1D shard_map protocol), and on a true ``(4, 2)`` mesh —
     losses agree to fp32 tolerance,
  3. let ``engine="auto"`` place an oversized replica: a footprint hint
     over the per-device budget routes onto mesh_2d with just enough
     model shards to fit (the ``launch/dryrun --mesh-report`` table shows
     the same arithmetic for the real arch zoo),
  4. train under a non-dividing client count — pad rows are copies of
     client 0, masked out of the Eq.-7b mean.

Needs >= 8 devices; on CPU run with forced host devices:

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/mesh_quickstart.py
"""
import os

import jax
import numpy as np

from repro.api import FederationSpec, init_state, resolve_engine, run_round
from repro.launch.mesh import make_mesh_2d
from repro.mesh.placement import ENV_DEVICE_MEM, default_mesh_shape
from repro.models.linear import init_linear, logreg_loss
from repro.models.sharding import axis_rules, mesh2d_rules, resolve_spec
from repro.optim import sgd

if jax.device_count() < 8:
    raise SystemExit(
        f"need 8 devices, have {jax.device_count()} — run with "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")

C, TAU, DIM, BATCH = 8, 3, 16, 4
SIGMA, LR = 0.6, 0.3


def spec_for(engine, n_clients=C, **kw):
    return FederationSpec(
        n_clients=n_clients, tau=TAU, loss_fn=logreg_loss,
        optimizer=sgd(LR), engine=engine, dp=True, clip_norm=1.0,
        sigmas=(SIGMA,) * n_clients, batch_sizes=(BATCH,) * n_clients,
        kernel_backend="ref", **kw)


def one_round(spec, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "x": rng.normal(size=(spec.n_clients, TAU, BATCH, DIM)).astype(
            np.float32),
        "y": rng.integers(0, 2, size=(spec.n_clients, TAU, BATCH)).astype(
            np.int32),
    }
    state = init_state(spec, init_linear(DIM))
    state, rec = run_round(spec, state, batch)
    return float(rec["loss"])


print("== 1. the mesh and its logical-axis rules ==")
mesh = make_mesh_2d((4, 2))
print(f"   mesh axes {mesh.axis_names}, shape {dict(mesh.shape)}")
with axis_rules(mesh, mesh2d_rules()):
    for logical in [("fsdp", "tp"), ("batch", "seq", "tp"), ("client",)]:
        print(f"   {str(logical):28s} -> {resolve_spec(logical)}")

print("== 2. one DP round: vmap vs degenerate mesh vs true 2D mesh ==")
loss_vmap = one_round(spec_for("vmap"))
loss_degen = one_round(spec_for("mesh_2d", mesh_shape=(C, 1)))
loss_2d = one_round(spec_for("mesh_2d", mesh_shape=(4, 2)))
print(f"   vmap          {loss_vmap:.6f}")
print(f"   mesh (8,1)    {loss_degen:.6f}   (bitwise the shard_map plane)")
print(f"   mesh (4,2)    {loss_2d:.6f}   (params split over 2 model shards)")
assert abs(loss_2d - loss_vmap) < 1e-4

print("== 3. auto placement: an oversized replica routes onto mesh_2d ==")
replica = 100 * DIM * 4                    # synthetic footprint hint
os.environ[ENV_DEVICE_MEM] = str(4 * 1024)  # tiny per-device budget
try:
    auto = spec_for("auto", replica_bytes=replica)
    shape = default_mesh_shape(C, jax.device_count(), replica_bytes=replica)
    print(f"   replica {replica} B vs 4096 B/device budget -> "
          f"engine={resolve_engine(auto)}, mesh {shape} "
          f"({-(-replica // shape[1])} B per device)")
    print(f"   round loss {one_round(auto):.6f}")
finally:
    del os.environ[ENV_DEVICE_MEM]

print("== 4. non-dividing client count: C=6 on a (4,2) mesh ==")
loss_pad = one_round(spec_for("mesh_2d", n_clients=6, mesh_shape=(4, 2)))
loss_ref = one_round(spec_for("vmap", n_clients=6))
print(f"   mesh (4,2) C=6  {loss_pad:.6f}  vs vmap {loss_ref:.6f} "
      "(pad rows masked out of Eq. 7b)")
assert abs(loss_pad - loss_ref) < 1e-4
print("done.")

"""Adversarial-fleet quickstart: secure aggregation + byzantine robustness.

The paper's DP-PASGD trusts every device AND the server. PR 7's trust
plane relaxes both, as composable knobs on the aggregation seam. This
script shows the whole surface in ~1 minute on CPU:

  1. **secure aggregation** — clients upload pairwise-masked fixed-point
     updates; single uploads are mask noise to the server, yet the cohort
     sum (dropout-corrected) is EXACT. With the server reduced to
     sum-only, ``dp_accounting="central"`` models the round as one
     central Gaussian release and every zCDP charge shrinks by 1/P.
  2. **byzantine robustness** — 2 of 8 devices send boosted sign-flipped
     updates (the model-replacement poison). The participant mean
     collapses to chance; coordinate-median / trimmed-mean / norm-bound
     aggregators hold within a few accuracy points of the clean run.
  3. **population poisoning** — at M virtual clients there are no stable
     slots, so the malicious wrapper binds label-flip poisoning to vids.

Run:  PYTHONPATH=src python examples/robust_quickstart.py
"""
import numpy as np

from repro.api import FederationSpec, eval_params, init_state, train
from repro.models.linear import init_linear, logits, logreg_loss
from repro.optim import sgd

C, TAU, DIM, BATCH, ROUNDS = 8, 2, 16, 8, 15
rng_task = np.random.default_rng(0)
W_TRUE = rng_task.normal(size=DIM)
W_TRUE /= np.linalg.norm(W_TRUE)


def draw(rng, n):
    x = rng.normal(size=(n, DIM))
    x /= np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1.0)
    return x.astype(np.float32), (x @ W_TRUE > 0).astype(np.int32)


def sampler(m, tau, rng):
    x, y = draw(rng, tau * BATCH)
    return {"x": x.reshape(tau, BATCH, DIM), "y": y.reshape(tau, BATCH)}


EVAL_X, EVAL_Y = draw(np.random.default_rng(1), 2048)


def make_spec(**kw):
    return FederationSpec(
        n_clients=C, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.3),
        clip_norm=1.0, dp=True, sigmas=(0.05,) * C, batch_sizes=(BATCH,) * C,
        eps_th=1e9, c_th=1e9, **kw)


def run(spec):
    state = init_state(spec, init_linear(DIM))
    state, out = train(spec, state, sampler, max_rounds=ROUNDS)
    z = np.asarray(logits(eval_params(spec, state), EVAL_X))
    return float((z.argmax(axis=-1) == EVAL_Y).mean()), out


# -- 1. secure aggregation + central accounting -----------------------------
# the identity codec keeps the plain run on the pipeline PRNG schedule, so
# the two runs draw the SAME DP noise and differ only by mask quantization
plain = make_spec(compressor="topk", compression_ratio=1.0)
secure = make_spec(secure_agg=True, dp_accounting="central")
acc_p, out_p = run(plain)
acc_s, out_s = run(secure)
print("secure aggregation (server sees ONLY the masked cohort sum):")
print(f"  plain  mean round: acc={acc_p:.3f}  "
      f"eps={out_p['history'][-1]['max_epsilon']:.3f} (local accounting)")
print(f"  secure mean round: acc={acc_s:.3f}  "
      f"eps={out_s['history'][-1]['max_epsilon']:.3f} "
      f"(central: every charge / P={C})")
print(f"  same model to quantization precision "
      f"(|acc delta|={abs(acc_s - acc_p):.4f}); the privacy claim moved "
      f"from per-client releases to the single aggregate.\n")

# -- 2. the attack matrix: boosted flip vs every aggregator -----------------
print(f"attack matrix (2 of {C} byzantine, boosted sign-flip -25x):")
for agg, kw in [("mean", {}), ("median", {}),
                ("trimmed_mean", dict(trim_fraction=0.25)),
                ("norm_bound", dict(norm_bound_factor=2.0))]:
    clean, _ = run(make_spec(aggregator=agg, **kw))
    hit, _ = run(make_spec(aggregator=agg, attack="scale",
                           attack_scale=-25.0, byzantine_fraction=0.25,
                           **kw))
    verdict = "COLLAPSED" if clean - hit > 0.1 else "held"
    print(f"  {agg:13s} clean={clean:.3f}  attacked={hit:.3f}  "
          f"drop={clean - hit:+.3f}  {verdict}")
print("  the mean is dragged by the boosted minority; the robust "
      "reductions are coordinate-bounded by the honest rows.\n")

# -- 3. population-mode poisoning: malicious vids ---------------------------
from repro.population import is_byzantine_vid, malicious_population
from repro.population import synthetic_population

M = 10_000
pop = synthetic_population(M, dim=DIM, batch_size=BATCH)
mal = malicious_population(pop, byzantine_fraction=0.25, seed=7)
flags = [is_byzantine_vid(v, 0.25, 7) for v in range(M)]
shard = mal.sampler(int(np.argmax(flags)), TAU,
                    np.random.default_rng(0))
print(f"population poisoning ({mal.name}):")
print(f"  {sum(flags)}/{M} vids byzantine (per-vid deterministic draw, "
      f"O(1) membership — no M-length table)")
print(f"  byzantine vid serves flipped labels: y[:4]={shard['y'][0][:4]} "
      f"(features bit-unchanged; honest vids bit-identical to the base "
      f"population)")
print("  update-level attacks stay resident-only — a cohort slot hosts a "
      "different vid every round, so corruption must ride the data path.")

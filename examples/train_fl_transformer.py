"""End-to-end driver: federated DP-PASGD training of a ~100M-param
transformer on the synthetic non-iid token task for a few hundred steps.

This is the paper's algorithm at language-model scale, driven through the
``repro.api`` facade: C clients each take tau local noisy-SGD steps on their
own token distribution, then average. Default config (~110M params:
gemma3-family, 6 layers, d=768) trains a few hundred iterations in roughly
an hour on this CPU container; pass --tiny for a 2-minute sanity run. On a
TPU pod the same driver + launch/dryrun.py shardings run the full assigned
configs (switch the spec to ``engine="shard_map"`` for the explicit
collective schedule).

Run:  PYTHONPATH=src python examples/train_fl_transformer.py --tiny
"""
import argparse
import time
from dataclasses import replace

import jax

from repro.api import train
from repro.configs import get_arch
from repro.configs.base import LayerSpec, Segment
from repro.core.privacy import sigma_star
from repro.launch.train import build_federation

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--rounds", type=int, default=0)
ap.add_argument("--engine", default="auto",
                choices=("vmap", "map", "shard_map", "auto"))
args = ap.parse_args()

base = get_arch("gemma3-4b")
if args.tiny:
    cfg = replace(
        base, name="gemma3-tiny", d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab=2048, n_layers=6, window=64,
        segments=(Segment(1, (LayerSpec(attn_kind="swa"),) * 5
                          + (LayerSpec(attn_kind="full"),)),),
        loss_chunk=0, block_q=64, dtype="float32", remat=False)
    rounds = args.rounds or 8
    batch, seq, tau = 8, 64, 4
else:
    # ~110M params: 6-layer gemma3-family stack, d=768, 32k vocab
    cfg = replace(
        base, name="gemma3-110m", d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=3072, vocab=32768, n_layers=6, window=256,
        segments=(Segment(1, (LayerSpec(attn_kind="swa"),) * 5
                          + (LayerSpec(attn_kind="full"),)),),
        loss_chunk=0, block_q=128, dtype="float32", remat=False)
    rounds = args.rounds or 50
    batch, seq, tau = 8, 256, 8

DELTA, C = 1e-5, 4
K = rounds * tau
if args.tiny:
    # At toy scale, per-coordinate DP noise at a practical eps swamps the
    # signal (exactly the paper's accuracy-privacy trade-off); the tiny demo
    # uses a weak privacy level and reports the eps it actually spends.
    CLIP, sigma, EPS = 20.0, 0.1, float("inf")
else:
    CLIP, EPS = 1.0, 8.0
    sigma = sigma_star(K, CLIP, batch, EPS, DELTA)
print(f"arch={cfg.name} clients={C} tau={tau} rounds={rounds} "
      f"sigma={sigma:.4f} (eps budget={EPS})")

model, spec, state, sampler = build_federation(
    cfg, n_clients=C, tau=tau, batch_size=batch, seq_len=seq,
    sigmas=[sigma] * C, lr=0.05, clip_norm=CLIP, delta=DELTA,
    engine=args.engine)
spec = spec.replace(eps_th=EPS)
n_params = sum(x.size for x in jax.tree.leaves(state.params)) // C
print(f"params/client: {n_params/1e6:.1f}M")

t0 = time.time()
state, out = train(spec, state, sampler, max_rounds=rounds)
losses = [h["loss"] for h in out["history"]]
print(f"iterations={out['rounds'] * tau}  loss {losses[0]:.3f} -> "
      f"best {min(losses):.3f}  eps spent={out['max_epsilon']:.3f}  "
      f"wall={time.time()-t0:.0f}s")
assert min(losses) < losses[0], "DP-PASGD should reduce training loss"

"""Quickstart: DP-PASGD on the (synthetic) Adult federated split.

Reproduces the paper's core loop in ~1 minute on CPU via the ``repro.api``
facade:
  1. build the non-iid federation (16 devices split by education),
  2. solve the optimal design (K*, tau*, sigma*) for the budgets,
  3. declare the run as one FederationSpec, init_state, and train with
     DP-PASGD until a budget binds — reporting accuracy + spent privacy.

The engine (vmap / map / shard_map) and the topology (full_average /
local_only ablation) are plain spec fields; swap them without touching the
training loop.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import FederationSpec, init_state, train
from repro.core.convergence import ProblemConstants
from repro.core.design import DesignProblem, ResourceModel
from repro.data import adult_like, split_by_group
from repro.models.linear import init_linear, logreg_loss, make_eval_fn
from repro.optim import sgd

C_TH, EPS_TH, DELTA = 1000.0, 4.0, 1e-4
BATCH, LR, CLIP = 32, 0.3, 1.0

print("== 1. data: non-iid Adult-like federation (split by education) ==")
ds = adult_like(n=8000, dim=40)
fed_data = split_by_group(ds)
print(f"   {fed_data.n_clients} clients, "
      f"sizes {[c.n_train for c in fed_data.clients][:6]}...")

print("== 2. optimal schematic design (paper Eq. 21-25) ==")
consts = ProblemConstants(eta=LR, lam=0.1, lip=0.3, alpha=0.8, xi2=0.05,
                          dim=2 * 40 + 2, n_clients=fed_data.n_clients)
problem = DesignProblem(
    consts=consts, resource=ResourceModel(c1=100.0, c2=1.0),
    clip_norm=CLIP, batch_sizes=fed_data.batch_sizes(BATCH),
    delta=DELTA, eps_th=EPS_TH, c_th=C_TH)
sol = problem.solve()
print(f"   K*={sol.k}  tau*={sol.tau}  sigma*={sol.sigmas[0]:.4f}  "
      f"predicted bound={sol.predicted_bound:.4f}  cost={sol.cost:.0f}")

print("== 3. train DP-PASGD until the budgets bind ==")
spec = FederationSpec(
    n_clients=fed_data.n_clients, tau=sol.tau,
    loss_fn=logreg_loss, optimizer=sgd(LR),
    clip_norm=CLIP, dp=True, engine="auto",
    sigmas=tuple(float(s) for s in sol.sigmas),
    batch_sizes=tuple(fed_data.batch_sizes(BATCH)),
    eps_th=EPS_TH, delta=DELTA, c_th=C_TH)
state = init_state(spec, init_linear(40))
xt, yt = fed_data.eval_arrays("test")
state, out = train(spec, state, fed_data.make_sampler(BATCH),
                   max_rounds=sol.k // sol.tau,
                   eval_fn=make_eval_fn(logreg_loss, xt, yt))
print(f"   rounds={out['rounds']}  best acc={out['best'].get('eval_acc'):.4f}"
      f"  spent eps={out['max_epsilon']:.3f} (budget {EPS_TH})"
      f"  spent C={out['resource_spent']:.0f} (budget {C_TH})")
assert out["max_epsilon"] <= EPS_TH + 1e-6

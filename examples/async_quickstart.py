"""Buffered-async quickstart: beating the sync barrier on a straggler fleet.

Real IoT fleets are heterogeneous: the devices most likely to drop rounds
are also the slowest to compute and upload. A synchronous round waits for
the slowest of all K clients every round; FedBuff-style buffered
asynchrony (``repro.asyncfl``) waits only for the B earliest arrivals,
folds them into the global model staleness-weighted, and immediately
redispatches — so the virtual clock advances at the pace of the fast
devices while the stragglers' (pre-charged!) uploads land in later
buffers. This script shows the whole surface in ~1 minute on CPU:

  1. build a correlated straggler fleet: ``HeteroLatency`` draws per-device
     compute+upload times from the SAME Beta-availability rates as the
     PR-5 ``HeterogeneousCohort`` sampler (flaky == slow),
  2. train the same federation twice — sync barrier vs async B-of-K —
     and compare **simulated seconds to the same amount of landed zCDP**
     (equal client updates processed, so the model-quality budget is
     identical; only the clock differs),
  3. inspect the dispatch-split privacy ledger: the budget probes read
     landed + in-flight rho, so a straggler can never outrun them.

Run:  PYTHONPATH=src python examples/async_quickstart.py
"""
import numpy as np

from repro.api import FederationSpec, init_state, run_round
from repro.api.state import round_batch
from repro.asyncfl import (
    HeteroLatency,
    dispatched_epsilon,
    dispatched_rho,
    init_async_state,
    run_async_cycle,
    sync_round_duration,
    train_async,
)
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd

K, B, TAU, DIM, BATCH = 8, 2, 2, 32, 8
SYNC_ROUNDS = 12                      # async runs the same update count
FLUSHES = SYNC_ROUNDS * K // B


def sampler(m, tau, rng):
    r = np.random.default_rng((13, int(m)))   # fixed per-client shard
    return {"x": r.normal(size=(tau, BATCH, DIM)).astype(np.float32),
            "y": r.integers(0, 2, size=(tau, BATCH)).astype(np.int32)}


def make_spec(**kw):
    return FederationSpec(
        n_clients=K, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.3),
        clip_norm=1.0, dp=True, sigmas=(0.5,) * K, batch_sizes=(BATCH,) * K,
        eps_th=1e9, c_th=1e9, **kw)


# -- 1. the fleet: availability-correlated straggler clocks -----------------
lat = HeteroLatency(0, fleet=K, slow_factor=6.0)
rates = lat.rates()
means = lat.mean_latency(np.arange(K))
print("fleet (availability rate -> mean compute seconds):")
for v in np.argsort(rates):
    bar = "#" * int(means[v] * 6)
    print(f"  device {v}: rate={rates[v]:.2f}  mean={means[v]:5.2f}s {bar}")

# -- 2a. sync barrier: every round waits for the slowest device -------------
sync_spec = make_spec(engine="vmap")
state = init_state(sync_spec, init_linear(DIM))
rng = np.random.default_rng(0)
sync_clock = 0.0
for r in range(SYNC_ROUNDS):
    state, rec = run_round(sync_spec, state, round_batch(sync_spec, sampler,
                                                         rng))
    sync_clock += sync_round_duration(lat, K, r)
sync_eps = rec["max_epsilon"]
print(f"\nsync   : {SYNC_ROUNDS} rounds ({SYNC_ROUNDS * K} client updates) "
      f"in {sync_clock:8.2f} simulated seconds (eps={float(sync_eps):.2f})")

# -- 2b. buffered async: flush on the B earliest arrivals -------------------
async_spec = make_spec(engine="async_buffered", buffer_size=B,
                       staleness_alpha=0.5)
rng = np.random.default_rng(0)
ast = init_async_state(async_spec, init_linear(DIM), sampler, rng=rng,
                       latency_model=lat)
ast, out = train_async(async_spec, ast, sampler, max_rounds=FLUSHES,
                       rng=rng, chunk_rounds=8, latency_model=lat)
print(f"async  : {FLUSHES} flushes of B={B} (same {FLUSHES * B} updates) "
      f"in {out['sim_seconds']:8.2f} simulated seconds "
      f"(eps={out['max_epsilon']:.2f})")
print(f"speedup: {sync_clock / out['sim_seconds']:.2f}x simulated "
      f"wall-clock at the same TOTAL landed zCDP across the fleet "
      f"(per-client eps skews async: fast devices are dispatched — and "
      f"charged — more often)")

# -- 3. the dispatch-split ledger ------------------------------------------
print("\ndispatch-split zCDP ledger (landed + in-flight = committed):")
for v in range(K):
    print(f"  device {v}: landed={ast.fl.rho[v]:6.3f}  "
          f"in-flight={ast.pending_rho[v]:5.3f}  "
          f"committed={dispatched_rho(ast)[v]:6.3f}  "
          f"({int(ast.arrivals[v])} arrivals)")
print(f"budget probes read the committed view: eps_dispatched="
      f"{dispatched_epsilon(async_spec, ast):.2f} — a straggler's noise is "
      f"charged when its round is HANDED OUT, not when the upload lands.")
slow, fast = int(np.argmin(rates)), int(np.argmax(rates))
print(f"note the skew: flaky device {slow} landed "
      f"{int(ast.arrivals[slow])} uploads vs {int(ast.arrivals[fast])} for "
      f"reliable device {fast} — staleness weighting (alpha=0.5) damps the "
      f"old versions it trains on.")

"""Continuous-batching walkthrough: federate a model, checkpoint it,
serve it under an open-loop Poisson load on the slot engine, then
hot-swap a fresh federated checkpoint mid-stream without dropping the
requests that are already decoding.

Four acts, all through public entry points:

  1. federate   — two DP-PASGD rounds on a tiny gemma3 via ``repro.api``
                  produce checkpoint A; two more rounds produce B
  2. serve      — ``SlotEngine`` + ``serve_continuous`` drain a Poisson
                  workload against checkpoint A; the report carries
                  tokens/s, p50/p99 latency, queue depth, occupancy
  3. hot-swap   — the same workload replayed with ``swap_at`` set mid-
                  stream: the engine donates A's param buffers to B at a
                  decode-step boundary, in-flight requests finish on B
  4. exactness  — every served request is byte-identical to the static
                  ``generate`` path on whichever params were live

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""
import tempfile

import jax
import numpy as np

from repro.api import FederationSpec, init_state, run_round, save_state
from repro.configs import get_arch, smoke_variant
from repro.data.tokens import FederatedTokenStream, TokenTaskConfig
from repro.launch.serve import generate, load_federated_params
from repro.launch.train import federation_meta
from repro.models.transformer import Transformer
from repro.optim import sgd
from repro.serve import (SlotEngine, StepClock, poisson_workload,
                         serve_continuous)

# ---- 1. federate: two checkpoints, two rounds apart ------------------------
C, TAU, BATCH, SEQ = 4, 2, 2, 16
cfg = smoke_variant(get_arch("gemma3-4b"))
model = Transformer(cfg)
spec = FederationSpec(
    n_clients=C, tau=TAU, loss_fn=model.loss_fn, optimizer=sgd(0.05),
    dp=True, clip_norm=5.0, sigmas=(0.01,) * C, batch_sizes=(BATCH,) * C)
stream = FederatedTokenStream(TokenTaskConfig(vocab=cfg.vocab, seq_len=SEQ,
                                              n_clients=C, seed=0),
                              BATCH)
state = init_state(spec, model.init(jax.random.PRNGKey(0)))
rng = np.random.default_rng(0)


def rounds(state, n):
    for _ in range(n):
        per_client = [stream.sampler(m, TAU, rng) for m in range(C)]
        batch = jax.tree.map(lambda *xs: np.stack(xs), *per_client)
        state, rec = run_round(spec, state, batch, check_budgets=False)
    return state, float(rec["loss"])


with tempfile.TemporaryDirectory() as ckpt_a, \
        tempfile.TemporaryDirectory() as ckpt_b:
    state, loss_a = rounds(state, 2)
    save_state(ckpt_a, state, extra=federation_meta(spec))
    state, loss_b = rounds(state, 2)
    save_state(ckpt_b, state, extra=federation_meta(spec))
    params_a = load_federated_params(model, ckpt_a)
    params_b = load_federated_params(model, ckpt_b)
print(f"federated: checkpoint A after 2 rounds (loss={loss_a:.3f}), "
      f"B after 4 (loss={loss_b:.3f})")

# ---- 2. serve checkpoint A under Poisson load ------------------------------
workload = poisson_workload(8, rate=2.0, vocab=cfg.vocab, seed=3,
                            prompt_lens=(8, 16), gen_lens=(6, 10))
engine = SlotEngine(model, params_a, n_slots=3, max_len=32, block_size=8)
engine.warmup(buckets=[r.prompt_len for r in workload])
report = serve_continuous(engine, workload, clock=StepClock())
s = report.summary()
print(f"served {s['requests']} requests / {s['tokens_out']} tokens on "
      f"{engine.n_slots} slots: p50={s['p50_latency_s']}s "
      f"p99={s['p99_latency_s']}s queue<= {s['max_queue_depth']} "
      f"occupancy={s['occupancy_mean']}")

# ---- 3. replay with a mid-stream hot-swap to checkpoint B ------------------
workload2 = poisson_workload(8, rate=2.0, vocab=cfg.vocab, seed=3,
                             prompt_lens=(8, 16), gen_lens=(6, 10))
engine2 = SlotEngine(model, params_a, n_slots=3, max_len=32, block_size=8)
engine2.warmup(buckets=[r.prompt_len for r in workload2])
swap_at = workload2[3].arrival  # boundary lands mid-decode for early reqs
report2 = serve_continuous(engine2, workload2, clock=StepClock(),
                           swap_at=swap_at, swap_params=params_b)
assert engine2.stats()["swaps"] == 1
assert all(r.finished for r in report2.requests)
print(f"hot-swapped A->B at t={swap_at:.2f}s; all {len(report2.requests)} "
      f"in-flight and later requests completed")

# ---- 4. exactness: engine tokens == static generate on the live params ----
diverged = 0
for r, r2 in zip(report.requests, report2.requests):
    prompts = r.tokens[None, :].astype(np.int32)
    ref_a = np.asarray(generate(model, params_a, prompts, r.max_gen))[0]
    assert r.out == ref_a.tolist(), f"rid={r.rid} diverged from generate(A)"
    if r2.emit_times[0] >= swap_at and r2.arrival >= swap_at:
        ref_b = np.asarray(generate(model, params_b, prompts, r.max_gen))[0]
        assert r2.out == ref_b.tolist()
    diverged += r.out != r2.out
print(f"byte-identical to generate() per live checkpoint; "
      f"{diverged}/{len(report.requests)} requests changed tokens across "
      f"the swap boundary")

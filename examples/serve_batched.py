"""Batched serving example: prefill a batch of prompts on a reduced
zamba2-family (Mamba2 + shared attention) model and decode with the cached
state — exercises the hybrid KV/SSM cache path.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.launch.serve import generate
from repro.models.transformer import Transformer

for arch in ("zamba2-7b", "rwkv6-1.6b", "gemma3-4b"):
    cfg = smoke_variant(get_arch(arch))
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(4, 24)), jnp.int32)
    prefix = None
    if cfg.prefix_len:
        prefix = jnp.asarray(rng.standard_normal((4, cfg.prefix_len,
                                                  cfg.d_model)),
                             jnp.float32) * 0.02
    t0 = time.time()
    out = generate(model, params, prompts, gen_tokens=12, prefix=prefix,
                   temperature=0.8)
    dt = time.time() - t0
    assert out.shape == (4, 12)
    assert np.isfinite(np.asarray(out, np.float64)).all()
    print(f"{arch:>14}: generated {out.shape} in {dt:.1f}s; "
          f"sample={np.asarray(out[0, :6]).tolist()}")

"""Batched serving example: prefill a batch of prompts on reduced
zamba2/rwkv6/gemma3-family models and decode with the cached state —
exercises the hybrid KV/SSM cache path.

The final section runs the whole federated loop through the ``repro.api``
facade: a tiny gemma3 federation takes two DP-PASGD rounds under the
aggregation pipeline (half the clients sampled per round, top-k compressed
updates with error feedback), checkpoints its ``FLState`` with
``save_state``, and the serving driver reloads the aggregated model via
``load_federated_params`` — train-to-serve with no pre-``repro.api``
entry points.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import FederationSpec, init_state, run_round, save_state
from repro.configs import get_arch, smoke_variant
from repro.data.tokens import FederatedTokenStream, TokenTaskConfig
from repro.launch.serve import generate, load_federated_params
from repro.launch.train import federation_meta
from repro.models.transformer import Transformer
from repro.optim import sgd

for arch in ("zamba2-7b", "rwkv6-1.6b", "gemma3-4b"):
    cfg = smoke_variant(get_arch(arch))
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(4, 24)), jnp.int32)
    prefix = None
    if cfg.prefix_len:
        prefix = jnp.asarray(rng.standard_normal((4, cfg.prefix_len,
                                                  cfg.d_model)),
                             jnp.float32) * 0.02
    t0 = time.time()
    out = generate(model, params, prompts, gen_tokens=12, prefix=prefix,
                   temperature=0.8)
    dt = time.time() - t0
    assert out.shape == (4, 12)
    assert np.isfinite(np.asarray(out, np.float64)).all()
    print(f"{arch:>14}: generated {out.shape} in {dt:.1f}s; "
          f"sample={np.asarray(out[0, :6]).tolist()}")

# ---- federate -> checkpoint -> serve (the repro.api loop) ------------------
C, TAU, BATCH, SEQ = 4, 2, 2, 16
cfg = smoke_variant(get_arch("gemma3-4b"))
model = Transformer(cfg)
spec = FederationSpec(
    n_clients=C, tau=TAU, loss_fn=model.loss_fn, optimizer=sgd(0.05),
    dp=True, clip_norm=5.0, sigmas=(0.01,) * C, batch_sizes=(BATCH,) * C,
    participation=0.5, compressor="topk", compression_ratio=0.25)
stream = FederatedTokenStream(TokenTaskConfig(vocab=cfg.vocab, seq_len=SEQ,
                                              n_clients=C, seed=0),
                              BATCH, prefix_len=cfg.prefix_len,
                              d_model=cfg.d_model)
state = init_state(spec, model.init(jax.random.PRNGKey(0)))
rng = np.random.default_rng(0)
for _ in range(2):
    per_client = [stream.sampler(m, TAU, rng) for m in range(C)]
    batch = jax.tree.map(lambda *xs: np.stack(xs), *per_client)
    state, rec = run_round(spec, state, batch, check_budgets=False)
print(f"federated 2 rounds (q=0.5, topk 25%): loss={float(rec['loss']):.3f} "
      f"participants/round={int(rec['participants'])} "
      f"comm cost x{spec.comm_scale():.3f}")

with tempfile.TemporaryDirectory() as ckpt:
    save_state(ckpt, state, extra=federation_meta(spec))
    served = load_federated_params(model, ckpt)
prompts = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 12)), jnp.int32)
out = generate(model, served, prompts, gen_tokens=8, temperature=0.8)
assert out.shape == (2, 8)
print(f"served the aggregated federated model: sample="
      f"{np.asarray(out[0, :6]).tolist()}")

"""Population quickstart: DP-PASGD over 100,000 virtual IoT devices.

Cross-device FL at IoT scale runs a small per-round *cohort* K drawn from a
huge *population* M >> K (the paper's resource-constrained fleet, scaled to
its intended setting). This script shows the whole ``repro.population``
surface in ~1 minute on CPU:

  1. synthesize a Dirichlet label-skew population of M = 100,000 virtual
     clients — lazy: a client's data exists only while it is in a cohort,
  2. declare the federation: ``FederationSpec(population=M, cohort_size=K)``
     with ``n_clients = K`` (the device block IS the cohort; device memory
     is bounded by K, independent of M),
  3. train with the fused chunked driver (cohorts resample at chunk
     boundaries) under a per-virtual-client privacy ledger held in the
     host-side ClientStore,
  4. compare uniform cohorts with the Beta-availability / dropout
     heterogeneity model, and checkpoint/resume the population state,
  5. go device-resident: ``train_population(..., resident_cache=S)``
     keeps S warm clients' sticky state on device and draws a FRESH
     cohort every round inside the fused scan — the per-round driver's
     exact schedule with zero steady-state host syncs.

Run:  PYTHONPATH=src python examples/population_quickstart.py
      PYTHONPATH=src python examples/population_quickstart.py --resident-cache 512
"""
import argparse
import tempfile

import numpy as np

from repro.api import FederationSpec
from repro.models.linear import init_linear, logreg_loss
from repro.optim import sgd
from repro.population import (
    HeterogeneousCohort,
    device_block_bytes,
    init_population_state,
    load_population_state,
    save_population_state,
    synthetic_population,
    train_population,
)

M, K = 100_000, 16            # population / per-round cohort
DIM, BATCH, TAU = 20, 8, 5
SIGMA, ROUNDS = 0.8, 24

ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
ap.add_argument("--resident-cache", type=int, default=256, metavar="S",
                help="warm-client slots for step 5's device-resident run "
                     "(must cover a chunk's cohort union, chunk_rounds*K; "
                     "default 256)")
args = ap.parse_args()

print(f"== 1. population: M={M:,} virtual clients, Dirichlet(0.3) skew ==")
pop = synthetic_population(M, dim=DIM, batch_size=BATCH, alpha=0.3, seed=0)
print(f"   lazy: client #71,231's shard is synthesized on demand -> "
      f"{pop.sampler(71_231, 1, np.random.default_rng(0))['x'].shape}")

print(f"== 2. spec: cohort_size=K={K} is the whole device block ==")
spec = FederationSpec(
    n_clients=K, tau=TAU, loss_fn=logreg_loss, optimizer=sgd(0.3),
    clip_norm=1.0, dp=True, population=M, cohort_size=K,
    compressor="topk", compression_ratio=0.25,     # IoT uplink budget
    sigmas=(SIGMA,) * K, batch_sizes=(BATCH,) * K, eps_th=1e9, c_th=1e9)
pstate = init_population_state(spec, init_linear(DIM))
print(f"   cohort fraction K/M = {spec.cohort_fraction():.2e}; device block "
      f"= {device_block_bytes(pstate):,} bytes regardless of M")

print("== 3. train: fused chunks, cohorts resampled per chunk ==")
pstate, out = train_population(spec, pstate, pop, max_rounds=ROUNDS,
                               chunk_rounds=8)
seen = int((pstate.store.rounds_participated > 0).sum())
print(f"   rounds={out['rounds']}  loss {out['history'][0]['loss']:.4f} -> "
      f"{out['history'][-1]['loss']:.4f}")
print(f"   ledger: {seen}/{M:,} clients ever sampled; worst-client "
      f"eps={out['max_epsilon']:.3f} (conditional per-realized-client "
      f"ledger); residual rows held: {pstate.store.residual_rows()}")

print("== 4. heterogeneity: Beta-availability fleet with 10% dropout ==")
hetero = HeterogeneousCohort(seed=1, availability=(8.0, 2.0), dropout=0.1)
hstate = init_population_state(spec, init_linear(DIM))
hstate, hout = train_population(spec, hstate, pop, cohort_sampler=hetero,
                                max_rounds=ROUNDS, chunk_rounds=8)
part = hstate.store.rounds_participated
print(f"   final loss {hout['history'][-1]['loss']:.4f}; busiest device ran "
      f"{int(part.max())} rounds (availability skew the per-vid ledger "
      f"tracks exactly)")

print("== 5. checkpoint / resume the population state ==")
with tempfile.TemporaryDirectory() as d:
    save_population_state(d, pstate, extra={"note": "quickstart"})
    resumed, extra = load_population_state(
        d, init_population_state(spec, init_linear(DIM)))
    assert resumed.fl.rounds_done == out["rounds"]
    assert np.array_equal(resumed.store.rho, pstate.store.rho)
    print(f"   restored round {resumed.fl.rounds_done} with "
          f"{resumed.store.residual_rows()} sparse residual rows "
          f"({extra['note']})")

print(f"== 6. device-resident: --resident-cache S={args.resident_cache} ==")
# a stationary population (sampler ignores its rng: each client re-reads a
# fixed local shard, the IoT regime) lets the cache hold DATA rows too —
# steady-state chunks then build no per-round host batches at all. The
# cohort now resamples EVERY round inside the fused scan (the per-round
# driver's exact schedule), not once per chunk; sticky state (error
# residual, per-vid rho) round-trips the host only on eviction/flush.
pop_res = synthetic_population(M, dim=DIM, batch_size=BATCH, alpha=0.3,
                               seed=0, stationary=True)
rstate = init_population_state(spec, init_linear(DIM))
rstate, rout = train_population(spec, rstate, pop_res, max_rounds=ROUNDS,
                                chunk_rounds=8,
                                resident_cache=args.resident_cache)
stats = rout["resident_cache"]
print(f"   loss {rout['history'][0]['loss']:.4f} -> "
      f"{rout['history'][-1]['loss']:.4f} over {rout['rounds']} rounds, "
      f"fresh cohort each round, zero steady-state host syncs")
print(f"   cache: {stats['hits']} hits / {stats['misses']} misses / "
      f"{stats['evictions']} evictions across {stats['flushes']} flush(es) "
      f"(S={args.resident_cache} warm of M={M:,})")

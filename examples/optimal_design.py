"""Optimal schematic design walk-through (paper §5-§7).

Shows how the solver trades tau against K and sigma as budgets move, and
compares against brute-force grid search on the Theorem-1 surrogate.

Run:  PYTHONPATH=src python examples/optimal_design.py
"""
from repro.core.convergence import ProblemConstants, theorem1_bound
from repro.core.design import DesignProblem, ResourceModel, grid_search_reference

consts = ProblemConstants(eta=0.05, lam=0.3, lip=1.5, alpha=2.0, xi2=0.4,
                          dim=82, n_clients=16)
resource = ResourceModel(c1=100.0, c2=1.0)

print(f"{'C_th':>6} {'eps_th':>7} | {'K*':>6} {'tau*':>5} {'sigma*':>8} "
      f"{'bound':>9} | {'grid tau':>8} {'grid bound':>10}")
for c_th in (300.0, 1000.0, 3000.0):
    for eps in (1.0, 4.0, 10.0):
        p = DesignProblem(consts=consts, resource=resource, clip_norm=1.0,
                          batch_sizes=[32] * 16, delta=1e-4, eps_th=eps,
                          c_th=c_th)
        sol = p.solve()
        gt, gk, gb = grid_search_reference(p, taus=range(1, 25))
        print(f"{c_th:6.0f} {eps:7.1f} | {sol.k:6d} {sol.tau:5d} "
              f"{sol.sigmas[0]:8.4f} {sol.predicted_bound:9.4f} | "
              f"{gt:8d} {gb:10.4f}")

print("\nclaims (paper §8.5): tau* falls as C_th rises; tau* rises with eps")
